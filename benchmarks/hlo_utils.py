"""Trip-count-aware accounting of FLOPs and collective bytes from HLO text.

Why not `compiled.cost_analysis()`: XLA's HLO cost analysis counts each
while-loop *body once*, but every model here wraps its depth (and
microbatches, and KV chunks) in `lax.scan` — so raw cost numbers are off by
the product of trip counts (measured ~1000x for deep scanned models).  This
module parses the post-SPMD HLO, builds the computation call graph, and
multiplies while bodies by their trip count, read from the loop's
`backend_config={"known_trip_count":{"n":...}}` (with the condition
computation's comparison constant as fallback).

Accounted per computation, then propagated through the call graph:
  - dot FLOPs: 2 * prod(output dims) * prod(lhs contracting dim sizes),
    looking operand shapes up in a per-module symbol table (post-SPMD HLO
    does not annotate operand shapes inline).  Elementwise VPU flops are
    excluded (noted in EXPERIMENTS.md §Roofline — matmuls dominate).
  - collective bytes: all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute; max(output, operand) bytes.

Post-partitioning shapes are per-device, so totals are per-chip.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

__all__ = ["analyze_hlo", "parse_collectives", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
_LINE_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
# First " op(" occurrence in the RHS is the opcode: tuple result shapes
# (with /*index=N*/ comments) never contain "word(" sequences.
_OPCODE_RE = re.compile(r"\s([\w\-]+)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')


def _split_op_line(line: str):
    """-> (result_name, result_shape_str, opcode, full_line) or None."""
    m = _LINE_RE.match(line)
    if not m:
        return None
    name, rhs = m.groups()
    om = _OPCODE_RE.search(" " + rhs)
    if not om:
        return None
    shape_str = rhs[: max(om.start() - 1, 0)]
    return name, shape_str, om.group(1), line.strip()


def _prod(xs):
    n = 1
    for x in xs:
        n *= x
    return n


def _shape_dims(shape_str: str):
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in DTYPE_BYTES:
            continue
        yield dtype, [int(d) for d in dims.split(",") if d]


def _shape_bytes(s: str) -> int:
    return sum(DTYPE_BYTES[dt] * _prod(d) for dt, d in _shape_dims(s))


@dataclass
class _Comp:
    name: str
    dot_flops: float = 0.0
    coll: dict = field(default_factory=lambda: defaultdict(float))
    calls: list = field(default_factory=list)  # (kind, callee, cond, trips)
    cond_const: int = 1
    mem_bytes: float = 0.0      # top-level op traffic (out + operands)


# Ops that move no HBM traffic themselves (or whose traffic is accounted by
# their called computation: while/conditional).  `copy` is excluded because
# the CPU backend's loop double-buffering inserts full-buffer copies every
# iteration that the TPU pipeline elides/aliases (measured ~50x traffic
# inflation on deep scanned models; EXPERIMENTS.md §Roofline method notes).
_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "after-all", "add-dependency", "domain",
    "partition-id", "replica-id", "copy",
}


def _split_computations(text: str):
    comps: dict[str, tuple[str, list[str]]] = {}
    cur: list[str] | None = None
    name = None
    entry = None
    for line in text.splitlines():
        if cur is None:
            m = _HEADER_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                name = m.group(2)
                if m.group(1):
                    entry = name
                cur = []
                comps[name] = (m.group(3), cur)
        else:
            if line.strip() == "}":
                cur = None
            else:
                cur.append(line)
    return comps, entry


_PARAM_RE = re.compile(r"%?([\w\.\-]+)\s*:\s*([\w\[\],\{\} ()]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _parse_comp(name: str, header_params: str, lines: list[str],
                inplace_comps: frozenset = frozenset()) -> _Comp:
    comp = _Comp(name)
    symbols: dict[str, str] = {}
    for pm in _PARAM_RE.finditer(header_params):
        symbols[pm.group(1)] = pm.group(2)
    ops = []
    for line in lines:
        parsed = _split_op_line(line)
        if parsed is None:
            continue
        res_name, res_shape, op, s = parsed
        symbols[res_name] = res_shape
        ops.append((res_name, res_shape, op, s))
    max_const = 1
    for res_name, res_shape, op, s in ops:
        cm = re.search(r"constant\((\d+)\)", s)
        if cm:
            max_const = max(max_const, int(cm.group(1)))
        if op == "dot":
            out_elems = sum(_prod(d) for _, d in _shape_dims(res_shape))
            args = s[s.index("(") + 1 :].split(")")[0]
            operands = [a.strip().lstrip("%") for a in args.split(",")]
            lhs_shape = symbols.get(operands[0], "") if operands else ""
            lhs_dims_list = list(_shape_dims(lhs_shape))
            lhs_dims = lhs_dims_list[0][1] if lhs_dims_list else []
            contract = 1
            dm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", s)
            if dm and dm.group(1):
                for i in dm.group(1).split(","):
                    idx = int(i)
                    if idx < len(lhs_dims):
                        contract *= lhs_dims[idx]
            comp.dot_flops += 2.0 * out_elems * contract
        elif op == "convolution":
            out_elems = sum(_prod(d) for _, d in _shape_dims(res_shape))
            comp.dot_flops += 2.0 * out_elems
        base = op
        for suffix in ("-start", "-done"):
            if base.endswith(suffix):
                base = base[: -len(suffix)]
        if base in _COLLECTIVES and not op.endswith("-done"):
            out_b = _shape_bytes(res_shape)
            in_b = 0
            if base == "reduce-scatter":
                args = s[s.index("(") + 1 :].split(")")[0]
                for a in args.split(","):
                    in_b += _shape_bytes(symbols.get(a.strip().lstrip("%"), ""))
            comp.coll[base] += max(out_b, in_b)
        # HBM traffic estimate: post-fusion top-level ops are the kernel
        # boundaries — each reads its operands and writes its result.
        # Slicing ops touch only the slice, not the (in-place) big buffer.
        if base not in _NO_TRAFFIC and not op.endswith("-done"):
            ops_args = []
            if "(" in s:
                args = s[s.index("(") + 1 :].split(")")[0]
                ops_args = [a.strip().lstrip("%") for a in args.split(",")]
            callee_m = _CALLS_RE.search(s) if op == "fusion" else None
            callee = callee_m.group(1) if callee_m else None
            if op == "dynamic-update-slice" and len(ops_args) > 1:
                upd = symbols.get(ops_args[1], "")
                comp.mem_bytes += 2 * _shape_bytes(upd)
            elif op in ("dynamic-slice", "slice"):
                comp.mem_bytes += 2 * _shape_bytes(res_shape)
            elif callee is not None and callee in inplace_comps:
                # Fusions containing dynamic-(update-)slice touch only the
                # slice of their big buffer (aliased / gathered lazily on
                # TPU): bill the output and the operands that are not the
                # sliced buffer itself.
                out_b = _shape_bytes(res_shape)
                comp.mem_bytes += out_b
                for a in ops_args:
                    ab = _shape_bytes(symbols.get(a, ""))
                    if ab <= out_b:
                        comp.mem_bytes += ab
            else:
                traffic = _shape_bytes(res_shape)
                for a in ops_args:
                    traffic += _shape_bytes(symbols.get(a, ""))
                comp.mem_bytes += traffic
        if op == "while":
            body = re.search(r"body=%?([\w\.\-]+)", s)
            cond = re.search(r"condition=%?([\w\.\-]+)", s)
            tm = _TRIP_RE.search(s)
            trips = int(tm.group(1)) if tm else None
            if body:
                comp.calls.append(
                    ("__while__", body.group(1), cond.group(1) if cond else None, trips)
                )
        else:
            for cm2 in _CALLS_RE.finditer(s):
                comp.calls.append(("__call__", cm2.group(1), None, 1))
            bm = _BRANCHES_RE.search(s)
            if bm:
                for callee in re.split(r",\s*", bm.group(1)):
                    callee = callee.strip().lstrip("%")
                    if callee:
                        comp.calls.append(("__call__", callee, None, 1))
    comp.cond_const = max_const
    return comp


def analyze_hlo(text: str) -> dict:
    raw, entry = _split_computations(text)
    inplace = frozenset(
        n for n, (_, ls) in raw.items()
        if any("dynamic-update-slice" in l or "dynamic-slice" in l
               or " slice(" in l for l in ls)
    )
    comps = {n: _parse_comp(n, hp, ls, inplace) for n, (hp, ls) in raw.items()}
    if entry is None and comps:
        entry = list(comps)[-1]

    memo: dict[str, tuple[float, dict, float]] = {}
    trips_seen: list[int] = []

    def visit(name: str, stack=()):
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return 0.0, {}, 0.0
        comp = comps[name]
        flops = comp.dot_flops
        coll = defaultdict(float, comp.coll)
        mem = comp.mem_bytes
        for kind, callee, cond, trips in comp.calls:
            cf, cc, cm = visit(callee, stack + (name,))
            mult = 1
            if kind == "__while__":
                if trips is not None:
                    mult = trips
                elif cond and cond in comps:
                    mult = comps[cond].cond_const
                trips_seen.append(mult)
            flops += cf * mult
            for k, v in cc.items():
                coll[k] += v * mult
            # Memory traffic: recurse only through control flow — fusion /
            # call computations are single kernels whose traffic is already
            # accounted at the call site.
            if kind == "__while__":
                mem += cm * mult
        memo[name] = (flops, dict(coll), mem)
        return memo[name]

    flops, coll, mem = visit(entry) if entry else (0.0, {}, 0.0)
    coll = dict(coll)
    coll["total"] = sum(v for k, v in coll.items() if k in _COLLECTIVES)
    return {
        "flops": flops,
        "collectives": coll,
        "hbm_bytes": mem,
        "while_trip_counts": trips_seen,
    }


def parse_collectives(hlo_text: str) -> dict:
    """Collective bytes with while-trip multiplication (see analyze_hlo)."""
    out = analyze_hlo(hlo_text)["collectives"]
    out.setdefault("total", 0.0)
    return out
