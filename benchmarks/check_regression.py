"""Cross-PR bench regression gate over BENCH_seeding.json (ROADMAP item).

CI snapshots the *committed* artifact (the previous PR's trajectory point)
before `benchmarks/run.py` overwrites it, then runs

    python benchmarks/check_regression.py --prev prev.json --cur BENCH_seeding.json

The gate fails when the per-open incremental sample-structure update
regresses **superlinearly**:

  * within the current artifact, the log-log slope of ``incremental_s``
    vs n across the microbench grid must stay below --max-slope (default
    1.0): the `TiledSampleTree.refresh` path is O(T log T) per open with
    T = n/tile, and measured slopes sit well under 1 (dispatch overhead
    amortises across the grid) — a superlinear fit means an O(n^>1)
    rebuild crept back into the per-open path;
  * within the current artifact, incremental must still beat the O(n)
    full rebuild at the largest n (--min-speedup, default 0.8 for noise);
  * against the previous artifact, the *growth ratio*
    ``incremental_s(n_max) / incremental_s(n_min)`` may not exceed the
    previous ratio by more than --slack (default 2.0).  Comparing growth
    shapes rather than absolute times keeps the gate robust to CI machines
    of different speeds while still catching a complexity-class regression.
    The comparison is restricted to grid points whose incremental time is
    at least --floor-us (default 100) in **both** artifacts: below that,
    the measurement is dominated by the fixed per-call dispatch floor, and
    the "growth ratio" measures the machine's dispatch overhead rather
    than the algorithm — a fast idle machine with a ~30us floor reports a
    3x larger ratio than a loaded CI runner for the *same* code.  When
    fewer than two comparable points remain the cross-artifact check is
    skipped with a note; the absolute in-artifact gates above still apply.

It also gates the adaptive candidate-batch schedule: the n=2^16 per-center
wall-clock under the adaptive schedule (min over reps, the noise-robust
statistic) must stay within --batch-slack (default 1.25) of the fixed
batch=128 baseline — "adaptive no worse than fixed" with timing-noise
headroom for shared CI runners.

And the serving-core robustness section (ISSUE 7): under the seeded
`FaultPlan` in `bench_robustness` the engine's goodput (completed /
submitted) must stay >= --min-goodput (default 0.95) and no ticket may be
stranded short of a terminal state — retry/fallback behaviour is
deterministic (seeded fault decisions), so a goodput drop is a resilience
regression, not noise.

And the continuous-batching serving section (ISSUE 8): on the seeded
open-loop Poisson trace of `bench_serving` the `ClusterFrontend` must
sustain >= --serving-min-speedup (default 2.0) times the requests/sec of
the one-request-per-solve engine baseline, at a p99 latency no worse
than --serving-p99-slack (default 1.25) times the baseline's, while
coalescing at least --serving-min-coalesce (default 0.3) of dispatched
requests into shared lanes — "2x throughput at equal p99", the
continuous-batching acceptance row.  The trace is seeded and replayed
identically against both paths on the same machine, so the ratios are
machine-speed-independent.

And the wire-transport serving subsection (ISSUE 9): on the seeded
loopback trace of `bench_serving_net` the `repro.serving.net` transport
may add at most --net-max-p99-overhead (default 1.5) times the
in-process frontend's p99 (both paths replay the identical trace on the
same warmed engine, best-of-reps, so the ratio isolates framing +
socket + serialisation cost from machine speed), and the per-tenant
Jain fairness index over equal-weight tenants must stay >=
--net-min-fairness (default 0.8) — a fairness collapse means the
weighted-fair dispatch hook stopped interleaving tenants.

And the streaming section (ISSUE 10), enforced under
``--extend-beats-reprep``: on the n=2^16 stream of `bench_streaming` the
incremental `ClusterPlan.extend` mutation must beat the from-scratch
`prepare_data` of the concatenated rows — the work incrementality
replaces; the solve-only refit is common to both paths and recorded,
not gated — by >= --streaming-min-speedup (default 1.2; both rounds
share data, seeds and warmed programs, so the ratio is
machine-independent),
the drift detector must have fired >= 1 reseed on the seeded
distribution shift, and the post-reseed clustering cost may be at most
--streaming-max-quality-ratio (default 1.5) times a from-scratch fit on
the same drifted live set.  Without the flag the checks still run
whenever the section is present; the flag makes its *absence* a failure
(the named CI step that just regenerated it must not silently no-op).

Fields absent from the previous artifact (older PRs) are skipped, so the
gate is self-bootstrapping.
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path


def _per_open(payload: dict) -> dict[int, float]:
    rec = payload.get("heap_update_per_open", {}).get("per_open", {})
    return {int(n): float(v["incremental_s"]) for n, v in rec.items()}


def _growth_ratio(per_open: dict[int, float]) -> float | None:
    if len(per_open) < 2:
        return None
    ns = sorted(per_open)
    return per_open[ns[-1]] / max(per_open[ns[0]], 1e-12)


def _loglog_slope(per_open: dict[int, float]) -> float | None:
    """Least-squares slope of log(incremental_s) vs log(n)."""
    if len(per_open) < 2:
        return None
    xs = [math.log(n) for n in sorted(per_open)]
    ys = [math.log(max(per_open[n], 1e-12)) for n in sorted(per_open)]
    mx = sum(xs) / len(xs)
    my = sum(ys) / len(ys)
    num = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
    den = sum((x - mx) ** 2 for x in xs)
    return num / den if den else None


def check(prev: dict, cur: dict, *, slack: float, max_slope: float,
          batch_slack: float, min_speedup: float,
          min_goodput: float = 0.95, floor_s: float = 1e-4,
          serving_min_speedup: float = 2.0,
          serving_p99_slack: float = 1.25,
          serving_min_coalesce: float = 0.3,
          net_max_p99_overhead: float = 1.5,
          net_min_fairness: float = 0.8,
          extend_beats_reprep: bool = False,
          streaming_min_speedup: float = 1.2,
          streaming_max_quality_ratio: float = 1.5) -> list[str]:
    """Returns a list of failure messages (empty = gate passes)."""
    failures = []
    cur_po = _per_open(cur)
    if not cur_po:
        failures.append("current artifact has no heap_update_per_open data")
        return failures

    slope = _loglog_slope(cur_po)
    if slope is not None and slope >= max_slope:
        failures.append(
            f"per-open incremental update grows superlinearly: log-log "
            f"slope {slope:.2f} >= {max_slope} over n={sorted(cur_po)}"
        )

    rec = cur.get("heap_update_per_open", {}).get("per_open", {})
    if rec:
        n_max = max(rec, key=int)
        speedup = float(rec[n_max].get("speedup", 0.0))
        if speedup < min_speedup:
            failures.append(
                f"incremental per-open update no longer beats the O(n) "
                f"rebuild at n={n_max}: speedup {speedup:.2f} < "
                f"{min_speedup}"
            )

    prev_po = _per_open(prev)
    if prev_po:
        # Growth shape is only measurable above the dispatch floor: keep
        # the grid points timed at >= floor_s on *both* machines, so the
        # ratio compares algorithmic growth, not per-call overhead.
        usable = sorted(n for n in set(cur_po) & set(prev_po)
                        if cur_po[n] >= floor_s and prev_po[n] >= floor_s)
        cur_ratio = _growth_ratio({n: cur_po[n] for n in usable})
        prev_ratio = _growth_ratio({n: prev_po[n] for n in usable})
        if cur_ratio is None or prev_ratio is None:
            print(
                f"note: cross-artifact growth check skipped — fewer than "
                f"two grid points above the {floor_s * 1e6:.0f}us dispatch "
                f"floor in both artifacts (in-artifact slope/speedup gates "
                f"still apply)"
            )
        elif cur_ratio > prev_ratio * slack:
            failures.append(
                f"per-open incremental growth ratio regressed "
                f"superlinearly vs previous artifact: "
                f"{cur_ratio:.2f} > {prev_ratio:.2f} * slack {slack} "
                f"over n={usable}"
            )

    ab = cur.get("adaptive_batch")
    if ab is None:
        failures.append("current artifact has no adaptive_batch record")
    else:
        ratio = float(ab.get("adaptive_over_fixed128", float("inf")))
        if ratio > batch_slack:
            failures.append(
                f"adaptive schedule per-center wall-clock is "
                f"{ratio:.3f}x the fixed batch=128 baseline "
                f"(> {batch_slack})"
            )

    rb = cur.get("robustness")
    if rb is None:
        failures.append("current artifact has no robustness record")
    else:
        goodput = float(rb.get("goodput", 0.0))
        if goodput < min_goodput:
            failures.append(
                f"serving goodput under the seeded FaultPlan dropped to "
                f"{goodput:.3f} (< {min_goodput}); "
                f"failures={rb.get('failures')}, "
                f"deadline_expired={rb.get('deadline_expired')}"
            )
        stranded = int(rb.get("stranded", -1))
        if stranded != 0:
            failures.append(
                f"{stranded} ticket(s) stranded short of a terminal state "
                f"under the chaos bench (must be 0)"
            )

    sv = cur.get("serving")
    if sv is None:
        failures.append("current artifact has no serving record")
    else:
        speedup = float(sv.get("speedup_req_per_s", 0.0))
        if speedup < serving_min_speedup:
            failures.append(
                f"continuous batching sustains only {speedup:.2f}x the "
                f"one-request-per-solve requests/sec "
                f"(< {serving_min_speedup}) on the seeded serving trace"
            )
        p99_ratio = float(sv.get("p99_ratio_vs_baseline", float("inf")))
        if p99_ratio > serving_p99_slack:
            failures.append(
                f"frontend p99 latency is {p99_ratio:.2f}x the solo "
                f"baseline's (> {serving_p99_slack}): coalescing is "
                f"buying throughput by holding requests too long"
            )
        coalesce = float(sv.get("frontend", {}).get("coalesce_rate", 0.0))
        if coalesce < serving_min_coalesce:
            failures.append(
                f"serving coalesce rate dropped to {coalesce:.2f} "
                f"(< {serving_min_coalesce}): lanes are dispatching "
                f"nearly empty on the seeded trace"
            )
        net = sv.get("net")
        if net is None:
            failures.append(
                "serving record has no net (wire transport) subsection"
            )
        else:
            overhead = float(net.get("p99_overhead_ratio", float("inf")))
            if overhead > net_max_p99_overhead:
                failures.append(
                    f"wire transport p99 is {overhead:.2f}x the "
                    f"in-process frontend's (> {net_max_p99_overhead}) "
                    f"on the seeded loopback trace: framing/socket/"
                    f"serialisation overhead regressed"
                )
            fairness = float(net.get("fairness_index", 0.0))
            if fairness < net_min_fairness:
                failures.append(
                    f"per-tenant Jain fairness index dropped to "
                    f"{fairness:.3f} (< {net_min_fairness}) over "
                    f"equal-weight tenants: weighted-fair dispatch is "
                    f"starving a tenant"
                )

    st = cur.get("streaming")
    if st is None:
        if extend_beats_reprep:
            failures.append(
                "current artifact has no streaming record "
                "(--extend-beats-reprep requires one)"
            )
    else:
        speedup = float(st.get("extend_speedup", 0.0))
        if speedup < streaming_min_speedup:
            failures.append(
                f"incremental extend is only {speedup:.2f}x the "
                f"from-scratch re-prepare "
                f"(< {streaming_min_speedup}) at n={st.get('n')}: "
                f"streaming is no longer cheaper than starting over"
            )
        drift = st.get("drift", {})
        reseeds = int(drift.get("reseeds", 0))
        if reseeds < 1:
            failures.append(
                "drift detector fired no reseed on the seeded "
                "distribution shift (expected >= 1): degradation goes "
                "unanswered"
            )
        quality = float(drift.get("post_reseed_cost_ratio_vs_fresh",
                                  float("inf")))
        if quality > streaming_max_quality_ratio:
            failures.append(
                f"post-reseed clustering cost is {quality:.2f}x a "
                f"from-scratch fit on the drifted live set "
                f"(> {streaming_max_quality_ratio}): the cheap reseed "
                f"stopped recovering quality"
            )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--prev", type=Path, required=True,
                    help="previous (committed) BENCH_seeding.json")
    ap.add_argument("--cur", type=Path, required=True,
                    help="freshly generated BENCH_seeding.json")
    ap.add_argument("--slack", type=float, default=2.0,
                    help="allowed growth-ratio inflation vs previous")
    ap.add_argument("--max-slope", type=float, default=1.0,
                    help="max log-log slope of incremental_s vs n")
    ap.add_argument("--batch-slack", type=float, default=1.25,
                    help="max adaptive/fixed128 per-center ratio")
    ap.add_argument("--min-speedup", type=float, default=0.8,
                    help="min incremental-vs-rebuild speedup at the "
                         "largest n")
    ap.add_argument("--min-goodput", type=float, default=0.95,
                    help="min engine goodput under the seeded FaultPlan")
    ap.add_argument("--floor-us", type=float, default=100.0,
                    help="dispatch-floor threshold (us): grid points timed "
                         "below this in either artifact are excluded from "
                         "the cross-artifact growth comparison")
    ap.add_argument("--serving-min-speedup", type=float, default=2.0,
                    help="min frontend requests/sec over the "
                         "one-request-per-solve baseline")
    ap.add_argument("--serving-p99-slack", type=float, default=1.25,
                    help="max frontend/baseline p99 latency ratio")
    ap.add_argument("--serving-min-coalesce", type=float, default=0.3,
                    help="min fraction of requests dispatched in lanes "
                         "of size >= 2")
    ap.add_argument("--net-max-p99-overhead", type=float, default=1.5,
                    help="max wire-transport/in-process p99 latency "
                         "ratio on the seeded loopback trace")
    ap.add_argument("--net-min-fairness", type=float, default=0.8,
                    help="min per-tenant Jain fairness index over "
                         "equal-weight tenants on the loopback trace")
    ap.add_argument("--extend-beats-reprep", action="store_true",
                    help="require the streaming section to exist and "
                         "pass (incremental extend beats re-prepare, "
                         "drift reseed fires, post-reseed quality holds)")
    ap.add_argument("--streaming-min-speedup", type=float, default=1.2,
                    help="min extend-then-refit speedup over the "
                         "re-prepare-then-fit baseline")
    ap.add_argument("--streaming-max-quality-ratio", type=float,
                    default=1.5,
                    help="max post-reseed cost vs a from-scratch fit on "
                         "the drifted live set")
    args = ap.parse_args(argv)
    prev = json.loads(args.prev.read_text()) if args.prev.exists() else {}
    cur = json.loads(args.cur.read_text())
    failures = check(prev, cur, slack=args.slack, max_slope=args.max_slope,
                     batch_slack=args.batch_slack,
                     min_speedup=args.min_speedup,
                     min_goodput=args.min_goodput,
                     floor_s=args.floor_us * 1e-6,
                     serving_min_speedup=args.serving_min_speedup,
                     serving_p99_slack=args.serving_p99_slack,
                     serving_min_coalesce=args.serving_min_coalesce,
                     net_max_p99_overhead=args.net_max_p99_overhead,
                     net_min_fairness=args.net_min_fairness,
                     extend_beats_reprep=args.extend_beats_reprep,
                     streaming_min_speedup=args.streaming_min_speedup,
                     streaming_max_quality_ratio=(
                         args.streaming_max_quality_ratio))
    for msg in failures:
        print(f"REGRESSION: {msg}", file=sys.stderr)
    if not failures:
        po = _per_open(cur)
        sv = cur["serving"]
        st = cur.get("streaming", {})
        st_note = (f", extend {st['extend_speedup']:.1f}x re-prepare "
                   f"({st['drift']['reseeds']} drift reseed(s), quality "
                   f"{st['drift']['post_reseed_cost_ratio_vs_fresh']:.2f}x)"
                   if st else "")
        print(f"bench regression gate ok: per-open incremental "
              f"slope={_loglog_slope(po):.2f}, growth "
              f"ratio={_growth_ratio(po):.2f}, adaptive/fixed128="
              f"{cur['adaptive_batch']['adaptive_over_fixed128']:.3f}, "
              f"goodput={cur['robustness']['goodput']:.3f}, "
              f"serving {sv['speedup_req_per_s']:.1f}x req/s at "
              f"p99 ratio {sv['p99_ratio_vs_baseline']:.2f} "
              f"(coalesce {sv['frontend']['coalesce_rate']:.2f}), "
              f"wire p99 overhead {sv['net']['p99_overhead_ratio']:.2f}x "
              f"(fairness {sv['net']['fairness_index']:.3f})"
              f"{st_note}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
