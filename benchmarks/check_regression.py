"""Cross-PR bench regression gate over BENCH_seeding.json (ROADMAP item).

CI snapshots the *committed* artifact (the previous PR's trajectory point)
before `benchmarks/run.py` overwrites it, then runs

    python benchmarks/check_regression.py --prev prev.json --cur BENCH_seeding.json

The gate fails when the per-open incremental sample-structure update
regresses **superlinearly**:

  * within the current artifact, the log-log slope of ``incremental_s``
    vs n across the microbench grid must stay below --max-slope (default
    1.0): the `TiledSampleTree.refresh` path is O(T log T) per open with
    T = n/tile, and measured slopes sit well under 1 (dispatch overhead
    amortises across the grid) — a superlinear fit means an O(n^>1)
    rebuild crept back into the per-open path;
  * within the current artifact, incremental must still beat the O(n)
    full rebuild at the largest n (--min-speedup, default 0.8 for noise);
  * against the previous artifact, the *growth ratio*
    ``incremental_s(n_max) / incremental_s(n_min)`` may not exceed the
    previous ratio by more than --slack (default 2.0).  Comparing growth
    shapes rather than absolute times keeps the gate robust to CI machines
    of different speeds while still catching a complexity-class regression.

It also gates the adaptive candidate-batch schedule: the n=2^16 per-center
wall-clock under the adaptive schedule (min over reps, the noise-robust
statistic) must stay within --batch-slack (default 1.25) of the fixed
batch=128 baseline — "adaptive no worse than fixed" with timing-noise
headroom for shared CI runners.

Fields absent from the previous artifact (older PRs) are skipped, so the
gate is self-bootstrapping.
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path


def _per_open(payload: dict) -> dict[int, float]:
    rec = payload.get("heap_update_per_open", {}).get("per_open", {})
    return {int(n): float(v["incremental_s"]) for n, v in rec.items()}


def _growth_ratio(per_open: dict[int, float]) -> float | None:
    if len(per_open) < 2:
        return None
    ns = sorted(per_open)
    return per_open[ns[-1]] / max(per_open[ns[0]], 1e-12)


def _loglog_slope(per_open: dict[int, float]) -> float | None:
    """Least-squares slope of log(incremental_s) vs log(n)."""
    if len(per_open) < 2:
        return None
    xs = [math.log(n) for n in sorted(per_open)]
    ys = [math.log(max(per_open[n], 1e-12)) for n in sorted(per_open)]
    mx = sum(xs) / len(xs)
    my = sum(ys) / len(ys)
    num = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
    den = sum((x - mx) ** 2 for x in xs)
    return num / den if den else None


def check(prev: dict, cur: dict, *, slack: float, max_slope: float,
          batch_slack: float, min_speedup: float) -> list[str]:
    """Returns a list of failure messages (empty = gate passes)."""
    failures = []
    cur_po = _per_open(cur)
    if not cur_po:
        failures.append("current artifact has no heap_update_per_open data")
        return failures

    slope = _loglog_slope(cur_po)
    if slope is not None and slope >= max_slope:
        failures.append(
            f"per-open incremental update grows superlinearly: log-log "
            f"slope {slope:.2f} >= {max_slope} over n={sorted(cur_po)}"
        )

    rec = cur.get("heap_update_per_open", {}).get("per_open", {})
    if rec:
        n_max = max(rec, key=int)
        speedup = float(rec[n_max].get("speedup", 0.0))
        if speedup < min_speedup:
            failures.append(
                f"incremental per-open update no longer beats the O(n) "
                f"rebuild at n={n_max}: speedup {speedup:.2f} < "
                f"{min_speedup}"
            )

    prev_po = _per_open(prev)
    cur_ratio = _growth_ratio(cur_po)
    prev_ratio = _growth_ratio(prev_po)
    if cur_ratio is not None and prev_ratio is not None:
        if cur_ratio > prev_ratio * slack:
            failures.append(
                f"per-open incremental growth ratio regressed "
                f"superlinearly vs previous artifact: "
                f"{cur_ratio:.2f} > {prev_ratio:.2f} * slack {slack}"
            )

    ab = cur.get("adaptive_batch")
    if ab is None:
        failures.append("current artifact has no adaptive_batch record")
    else:
        ratio = float(ab.get("adaptive_over_fixed128", float("inf")))
        if ratio > batch_slack:
            failures.append(
                f"adaptive schedule per-center wall-clock is "
                f"{ratio:.3f}x the fixed batch=128 baseline "
                f"(> {batch_slack})"
            )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--prev", type=Path, required=True,
                    help="previous (committed) BENCH_seeding.json")
    ap.add_argument("--cur", type=Path, required=True,
                    help="freshly generated BENCH_seeding.json")
    ap.add_argument("--slack", type=float, default=2.0,
                    help="allowed growth-ratio inflation vs previous")
    ap.add_argument("--max-slope", type=float, default=1.0,
                    help="max log-log slope of incremental_s vs n")
    ap.add_argument("--batch-slack", type=float, default=1.25,
                    help="max adaptive/fixed128 per-center ratio")
    ap.add_argument("--min-speedup", type=float, default=0.8,
                    help="min incremental-vs-rebuild speedup at the "
                         "largest n")
    args = ap.parse_args(argv)
    prev = json.loads(args.prev.read_text()) if args.prev.exists() else {}
    cur = json.loads(args.cur.read_text())
    failures = check(prev, cur, slack=args.slack, max_slope=args.max_slope,
                     batch_slack=args.batch_slack,
                     min_speedup=args.min_speedup)
    for msg in failures:
        print(f"REGRESSION: {msg}", file=sys.stderr)
    if not failures:
        po = _per_open(cur)
        print(f"bench regression gate ok: per-open incremental "
              f"slope={_loglog_slope(po):.2f}, growth "
              f"ratio={_growth_ratio(po):.2f}, adaptive/fixed128="
              f"{cur['adaptive_batch']['adaptive_over_fixed128']:.3f}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
