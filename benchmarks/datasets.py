"""Benchmark datasets matched to the paper's (n, d).

The UCI datasets the paper uses (KDD-Cup bio 311,029x74; Song 515,345x90;
Census 2,458,285x68) are not redistributable inside this offline container,
so the harness generates Gaussian-mixture data with matched dimensions and
heavy-tailed cluster structure (power-law cluster sizes + anisotropic
covariances — the regime where D^2 seeding matters).  `--scale` shrinks n
for CI-speed runs; the full (n, d) presets remain selectable.  DESIGN.md §3
records this substitution; every *relative* claim (C1/C2) is preserved.
"""

from __future__ import annotations

import numpy as np

__all__ = ["DATASETS", "make_dataset"]

DATASETS = {
    # name: (n_full, d, n_clusters)
    "kddcup": (311_029, 74, 2000),
    "song": (515_345, 90, 3000),
    "census": (2_458_285, 68, 4000),
}


def make_dataset(name: str, *, scale: float = 1.0, seed: int = 0) -> np.ndarray:
    n_full, d, k_true = DATASETS[name]
    n = max(1000, int(n_full * scale))
    k_true = max(20, int(k_true * min(scale * 4, 1.0)))
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k_true, d)) * 12.0
    # Power-law cluster sizes.
    weights = 1.0 / np.arange(1, k_true + 1) ** 1.3
    weights /= weights.sum()
    assign = rng.choice(k_true, size=n, p=weights)
    scales = rng.uniform(0.3, 3.0, size=(k_true, d))
    pts = centers[assign] + rng.normal(size=(n, d)) * scales[assign]
    return pts.astype(np.float64)
