"""Paper-table reproduction: seeding speed (Tables 1-3), quality (4-6),
variance (7-8), and rejection statistics (Lemma 5.3).

Speed tables report each algorithm's wall-clock divided by FASTK-MEANS++'s
(exactly the paper's presentation).  Quality tables report seeding costs.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

_ROOT = Path(__file__).resolve().parents[1]
for _p in (str(_ROOT), str(_ROOT / "src")):   # script mode: `python benchmarks/seeding.py`
    if _p not in sys.path:
        sys.path.insert(0, _p)

import numpy as np

from benchmarks.datasets import DATASETS, make_dataset

RESULTS = Path(__file__).resolve().parent / "artifacts"

ALGOS = ("fastkmeans++", "rejection", "kmeans++", "kmeans||", "afkmc2",
         "uniform")
# The paper's two algorithms (and the k-means|| oversampling baseline) also
# exist as jit-able device programs (`repro.core.device_seeding`) and as
# multi-chip shard_map programs (`repro.core.sharded_seeding`);
# `--backends cpu device sharded` appends these so Tables 1-3 can compare
# wall-clock for the same seeds.
DEVICE_ALGOS = ("fastkmeans++/device", "rejection/device", "kmeans||/device")
SHARDED_ALGOS = ("fastkmeans++/sharded", "rejection/sharded",
                 "kmeans||/sharded")


def _algo_list(backends) -> tuple[str, ...]:
    algos = tuple(ALGOS)
    if "device" in backends:
        algos += DEVICE_ALGOS
    if "sharded" in backends:
        algos += SHARDED_ALGOS
    return algos


def run_dataset(name: str, ks, *, scale: float, trials: int, seed: int = 0,
                backends=("cpu",)):
    from repro.core import SEEDERS, clustering_cost  # registers device algos
    from repro.core.preprocess import quantize

    algos = _algo_list(backends)
    pts = make_dataset(name, scale=scale, seed=seed)
    rng0 = np.random.default_rng(seed)
    q = quantize(pts, rng0)
    out = {"dataset": name, "n": len(pts), "d": pts.shape[1],
           "scale": scale, "ks": list(ks), "algos": {}}
    for algo in algos:
        out["algos"][algo] = {"seconds": {}, "prepare_seconds": {},
                              "solve_seconds": {}, "cost": {}, "var": {},
                              "trials_per_center": {}}
    for k in ks:
        for algo in algos:
            secs, prep_secs, solve_secs, costs, tpc = [], [], [], [], []
            if "/" in algo:
                # Warm-up: the first device/sharded call pays one-time jit
                # trace/compile; exclude it so the speed tables compare
                # steady-state seeding wall-clock, not XLA compilation.
                data = q.points
                SEEDERS[algo](data, k, np.random.default_rng(seed),
                              resolution=1.0)
            for t in range(trials):
                rng = np.random.default_rng(1000 * t + k)
                kwargs = {}
                data = pts
                if algo.split("/")[0] in ("fastkmeans++", "rejection"):
                    data = q.points          # Appendix-F quantised space
                    kwargs["resolution"] = 1.0
                res = SEEDERS[algo](data, k, rng, **kwargs)
                secs.append(res.seconds)
                prep_secs.append(res.prepare_seconds)
                solve_secs.append(res.solve_seconds)
                costs.append(clustering_cost(pts, pts[res.indices]))
                if res.num_candidates:
                    tpc.append(res.num_candidates / k)
            a = out["algos"][algo]
            a["seconds"][k] = float(np.mean(secs))
            a["prepare_seconds"][k] = float(np.mean(prep_secs))
            a["solve_seconds"][k] = float(np.mean(solve_secs))
            a["cost"][k] = float(np.mean(costs))
            a["var"][k] = float(np.var(costs))
            if tpc:
                a["trials_per_center"][k] = float(np.mean(tpc))
            print(f"  {name} k={k} {algo:14s} t={np.mean(secs):7.2f}s "
                  f"cost={np.mean(costs):.4g}", flush=True)
    return out


def print_tables(results: list[dict]):
    for res in results:
        ks = res["ks"]
        algos = tuple(res["algos"])
        base = res["algos"]["fastkmeans++"]["seconds"]
        print(f"\n== {res['dataset']} (n={res['n']}, d={res['d']}) — "
              f"runtime / FASTK-MEANS++ (paper Tables 1-3)")
        print(f"{'algorithm':20s}" + "".join(f" k={k:<8d}" for k in ks))
        for algo in algos:
            if algo == "uniform":
                continue
            row = res["algos"][algo]["seconds"]
            cells = "".join(f" {row[k]/max(base[k],1e-9):<9.2f}" for k in ks)
            print(f"{algo:20s}{cells}")
        print(f"-- seeding cost (paper Tables 4-6)")
        for algo in algos:
            row = res["algos"][algo]["cost"]
            cells = "".join(f" {row[k]:<12.4g}" for k in ks)
            print(f"{algo:20s}{cells}")
        print(f"-- cost variance over trials (paper Tables 7-8)")
        for algo in algos:
            row = res["algos"][algo]["var"]
            cells = "".join(f" {row[k]:<12.4g}" for k in ks)
            print(f"{algo:20s}{cells}")
        rej = res["algos"]["rejection"]["trials_per_center"]
        if rej:
            cells = "".join(f" {rej[k]:<9.1f}" for k in ks)
            print(f"-- rejection trials/center (Lemma 5.3 bound O(c^2 d^2)):"
                  f"\n{'rejection':18s}{cells}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", nargs="+", default=["kddcup", "song"],
                    choices=tuple(DATASETS))
    ap.add_argument("--ks", nargs="+", type=int, default=[100, 500, 1000])
    ap.add_argument("--scale", type=float, default=0.15,
                    help="fraction of the paper's n (1.0 = full)")
    ap.add_argument("--trials", type=int, default=2)
    ap.add_argument("--backends", nargs="+", default=["cpu"],
                    choices=("cpu", "device", "sharded"),
                    help="'device' appends the jit seeders "
                         "(fastkmeans++/device, rejection/device); "
                         "'sharded' the multi-chip shard_map seeders "
                         "(all local devices) — wall-clock comparison on "
                         "the same seeds")
    args = ap.parse_args(argv)
    RESULTS.mkdir(parents=True, exist_ok=True)
    results = []
    for name in args.datasets:
        results.append(run_dataset(name, args.ks, scale=args.scale,
                                   trials=args.trials,
                                   backends=tuple(args.backends)))
    (RESULTS / "seeding_results.json").write_text(json.dumps(results))
    print_tables(results)
    return results


if __name__ == "__main__":
    main()
