"""Render EXPERIMENTS.md tables from benchmark artifacts.

Fills the `<!-- *_TABLE -->` placeholders in EXPERIMENTS.md in place:
    python -m benchmarks.report
Idempotent: each placeholder's content is regenerated between marker lines.
"""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.roofline import ARTIFACTS, analyze, load_cells

ROOT = Path(__file__).resolve().parents[1]
EXPERIMENTS = ROOT / "EXPERIMENTS.md"

ALGOS = ("fastkmeans++", "rejection", "kmeans++", "afkmc2", "uniform")


def seeding_speed_table() -> str:
    path = ARTIFACTS / "seeding_results.json"
    if not path.exists():
        return "_(seeding benchmark not yet run)_"
    results = json.loads(path.read_text())
    out = []
    for res in results:
        ks = res["ks"]
        base = res["algos"]["fastkmeans++"]["seconds"]
        bget = lambda k: base.get(str(k), base.get(k))
        out.append(f"**{res['dataset']}** (n={res['n']:,}, d={res['d']}) — "
                   "absolute seconds, then ratio to FASTK-MEANS++:\n")
        out.append("| algorithm |" + "".join(f" k={k} |" for k in ks))
        out.append("|---|" + "---|" * len(ks))
        for algo in ALGOS:
            if algo == "uniform":
                continue
            sec = res["algos"][algo]["seconds"]
            get = lambda k: sec.get(str(k), sec.get(k))
            out.append(f"| {algo} (s) |" + "".join(
                f" {get(k):.2f} |" for k in ks))
        for algo in ALGOS:
            if algo == "uniform":
                continue
            sec = res["algos"][algo]["seconds"]
            get = lambda k: sec.get(str(k), sec.get(k))
            out.append(f"| {algo} (×fast) |" + "".join(
                f" {get(k)/max(bget(k),1e-9):.2f}x |" for k in ks))
        out.append("")
    return "\n".join(out)


def seeding_quality_table() -> str:
    path = ARTIFACTS / "seeding_results.json"
    if not path.exists():
        return "_(seeding benchmark not yet run)_"
    results = json.loads(path.read_text())
    out = []
    for res in results:
        ks = res["ks"]
        out.append(f"**{res['dataset']}** seeding cost (mean over trials):\n")
        out.append("| algorithm |" + "".join(f" k={k} |" for k in ks))
        out.append("|---|" + "---|" * len(ks))
        for algo in ALGOS:
            c = res["algos"][algo]["cost"]
            get = lambda k: c.get(str(k), c.get(k))
            out.append(f"| {algo} |" + "".join(f" {get(k):.4g} |" for k in ks))
        out.append("")
        out.append(f"variance over trials:\n")
        out.append("| algorithm |" + "".join(f" k={k} |" for k in ks))
        out.append("|---|" + "---|" * len(ks))
        for algo in ALGOS:
            v = res["algos"][algo]["var"]
            get = lambda k: v.get(str(k), v.get(k))
            out.append(f"| {algo} |" + "".join(f" {get(k):.3g} |" for k in ks))
        rej = res["algos"]["rejection"].get("trials_per_center", {})
        if rej:
            get = lambda k: rej.get(str(k), rej.get(k))
            out.append("")
            out.append("| rejection trials/center |" + "".join(
                f" {get(k):.1f} |" for k in ks))
        out.append("")
    return "\n".join(out)


def dryrun_table(mesh: str) -> str:
    out = ["| arch | shape | status | compile(s) | temp GiB/dev | "
           "args GiB/dev | HLO flops/dev | coll B/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for rec in load_cells(mesh):
        if rec.get("status") == "OK":
            mem = rec.get("memory_analysis", {})
            out.append(
                f"| {rec['arch']} | {rec['shape']} | OK | "
                f"{rec.get('compile_seconds', 0):.1f} | "
                f"{mem.get('temp_size_in_bytes', 0)/2**30:.1f} | "
                f"{mem.get('argument_size_in_bytes', 0)/2**30:.2f} | "
                f"{rec.get('hlo_flops', 0):.2e} | "
                f"{rec.get('collectives', {}).get('total', 0):.2e} |"
            )
        else:
            why = rec.get("reason", "")[:48]
            out.append(f"| {rec['arch']} | {rec['shape']} | "
                       f"{rec.get('status')} | — | — | — | — | {why} |")
    return "\n".join(out)


def roofline_table(mesh: str = "pod") -> str:
    out = ["| arch | shape | t_comp(s) | t_mem(s) | t_coll(s) | bound | "
           "useful | roofline |",
           "|---|---|---|---|---|---|---|---|"]
    for rec in load_cells(mesh):
        a = analyze(rec)
        if a is None:
            continue
        out.append(
            f"| {a['arch']} | {a['shape']} | {a['t_compute']:.3f} | "
            f"{a['t_memory']:.3f} | {a['t_collective']:.3f} | "
            f"{a['bottleneck']} | {a['useful_ratio']:.3f} | "
            f"{100*a['roofline_fraction']:.1f}% |"
        )
    return "\n".join(out)


MARKERS = {
    "SEEDING_SPEED_TABLE": seeding_speed_table,
    "SEEDING_QUALITY_TABLE": seeding_quality_table,
    "DRYRUN_TABLE": lambda: dryrun_table("pod") + "\n\n(multipod table: same "
    "cells at 512 chips — see artifacts; per-device numbers halve for "
    "DP-dominant cells.)",
    "ROOFLINE_TABLE": roofline_table,
}


def main():
    text = EXPERIMENTS.read_text()
    for marker, fn in MARKERS.items():
        tag = f"<!-- {marker} -->"
        end_tag = f"<!-- /{marker} -->"
        content = f"{tag}\n{fn()}\n{end_tag}"
        if end_tag in text:
            import re

            text = re.sub(
                re.escape(tag) + r".*?" + re.escape(end_tag),
                content.replace("\\", "\\\\"),
                text,
                flags=re.S,
            )
        else:
            text = text.replace(tag, content)
    EXPERIMENTS.write_text(text)
    print("EXPERIMENTS.md tables refreshed")


if __name__ == "__main__":
    main()
