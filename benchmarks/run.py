"""Benchmark harness entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus the formatted paper
tables) and writes the regression artifact ``BENCH_seeding.json`` at the
repo root — per-backend seeding wall-clock, clustering-cost ratios vs exact
CPU k-means++, and the per-open sample-structure update microbenchmark
(O(n) heap rebuild vs the incremental tile-sum scatter) — so every PR
leaves a perf trajectory point.  Sections:
  - seeding speed/quality/variance + rejection stats — paper Tables 1-8 on
    (n,d)-matched synthetic datasets (see datasets.py), CI scale by default;
  - per-open heap-update microbenchmark (rebuild vs incremental) at
    n in {2^14, 2^16, 2^18};
  - robustness — engine goodput / latency percentiles under a seeded
    `FaultPlan` (CI gates goodput >= 0.95 with zero stranded tickets);
  - serving — continuous-batching frontend vs one-request-per-solve on a
    seeded open-loop Poisson trace (CI gates >= 2x requests/sec at equal
    p99 plus a coalesce-rate floor);
  - serving.net — the same trace replayed over the loopback wire
    transport (`repro.serving.net`) vs the in-process frontend: wire
    req/s, added p99, per-tenant Jain fairness index (CI gates the
    p99 overhead ratio and a fairness floor; `--only serving
    --transport net` re-runs just this subsection);
  - streaming — incremental `ClusterPlan.extend` + solve-only refit vs
    re-prepare-then-fit at n=2^16, plus drift-reseed quality on a
    distribution shift (CI gates the extend speedup and the
    post-reseed cost via `check_regression.py --extend-beats-reprep`;
    `--only streaming` re-runs just this section);
  - kernel microbenchmarks — Pallas ops (interpret mode on CPU) vs jnp refs;
  - roofline — §Roofline summary from the dry-run artifacts (if present).
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path

_ROOT = Path(__file__).resolve().parents[1]
for _p in (str(_ROOT), str(_ROOT / "src")):   # script mode: `python benchmarks/run.py`
    if _p not in sys.path:
        sys.path.insert(0, _p)

import numpy as np

BENCH_JSON = _ROOT / "BENCH_seeding.json"


def _timeit(fn, *args, reps=3, warmup=1, **kw):
    for _ in range(warmup):
        fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / reps
    return dt, out


def bench_kernels():
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    rows = []
    for n, k, d in [(4096, 256, 64), (16384, 1024, 74)]:
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        c = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)
        dt, _ = _timeit(lambda: jax.block_until_ready(
            ops.pairwise_argmin(x, c)))
        rows.append((f"kernel.pairwise_argmin[{n}x{k}x{d}]", dt * 1e6,
                     f"{2*n*k*d/dt/1e9:.1f}GFLOP/s"))
        dtr, _ = _timeit(lambda: jax.block_until_ready(
            ref.pairwise_argmin_ref(x, c)))
        rows.append((f"ref.pairwise_argmin[{n}x{k}x{d}]", dtr * 1e6,
                     f"kernel_speedup_vs_ref={dtr/dt:.2f}x"))
        w = jnp.asarray(rng.uniform(1, 10, size=n), jnp.float32)
        dt, _ = _timeit(lambda: jax.block_until_ready(
            ops.d2_update(x, c[0], w)))
        rows.append((f"kernel.d2_update[{n}x{d}]", dt * 1e6, ""))
        dt, _ = _timeit(lambda: jax.block_until_ready(
            ops.d2_update_tiles(x, c[0], w)))
        rows.append((f"kernel.d2_update_tiles[{n}x{d}]", dt * 1e6,
                     "tile-sum epilogue for TiledSampleTree.refresh"))

    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.kernels.ref import flash_attention_ref

    bh, s, d = 4, 512, 64
    q = jnp.asarray(rng.normal(size=(bh, s, d)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(bh, s, d)), jnp.float32)
    vv = jnp.asarray(rng.normal(size=(bh, s, d)), jnp.float32)
    dt, out = _timeit(lambda: jax.block_until_ready(flash_attention_pallas(
        q, kk, vv, scale=d ** -0.5, causal=True, interpret=True)), reps=1)
    ref = flash_attention_ref(q, kk, vv, scale=d ** -0.5, causal=True)
    err = float(jnp.abs(out - ref).max())
    rows.append((f"kernel.flash_attention[{bh}x{s}x{d}]", dt * 1e6,
                 f"max_err_vs_exact={err:.1e}"))
    return rows


def bench_seeding(smoke: bool = False):
    from benchmarks.seeding import main as seeding_main

    if smoke:
        # CI-sized run: tiny slice of one dataset, CPU + device + sharded
        # backends so every jit seeder (Pallas kernels in interpret mode
        # off-TPU, shard_map over however many local devices exist) gets
        # exercised end-to-end on every push.
        argv = ["--datasets", "kddcup", "--ks", "25", "--scale", "0.01",
                "--trials", "1", "--backends", "cpu", "device", "sharded"]
    else:
        argv = ["--datasets", "kddcup", "--ks", "100", "500",
                "--scale", "0.05", "--trials", "1"]
    results = seeding_main(argv)
    rows = []
    for res in results:
        for algo, data in res["algos"].items():
            for k, secs in data["seconds"].items():
                rows.append((f"seed.{res['dataset']}.{algo}[k={k}]",
                             secs * 1e6,
                             f"cost={data['cost'][k]:.4g}"))
    return rows, results


def bench_adaptive_batch(n=1 << 16, d=16, k=8, reps=3):
    """Adaptive vs fixed-128 candidate batching (ISSUE 3 acceptance row).

    Times the full jit rejection program (Algorithm 4) at n = 2^16 under
    `BatchSchedule.fixed(128)` — the legacy block size — and the adaptive
    default, reporting *per-center* wall-clock.  Off-TPU the Pallas kernels
    run in interpret mode, so absolute numbers are not TPU-representative,
    but the two schedules share every sweep and differ only in the
    speculative-batch work — exactly the quantity the schedule adapts.
    """
    import jax

    from repro.core.batch_schedule import BatchSchedule
    from repro.core.device_seeding import (
        device_rejection_sampling,
        prepare_rejection,
    )

    rng = np.random.default_rng(0)
    ctr = rng.normal(size=(64, d)) * 20
    pts = ctr[rng.integers(64, size=n)] + rng.normal(size=(n, d))
    # Fixed resolution pins num_levels (a jit static) across runs.
    data = prepare_rejection(pts, seed=0, resolution=0.05)
    rows, record = [], {"n": n, "k": k, "d": d, "reps": reps,
                        "schedules": {}}
    for name, sched in (("fixed128", BatchSchedule.fixed(128)),
                        ("adaptive", BatchSchedule())):
        def run(key):
            return jax.block_until_ready(device_rejection_sampling(
                data.codes_lo, data.codes_hi, data.points,
                data.keys_lo, data.keys_hi, k, key,
                scale=data.scale, num_levels=data.num_levels,
                m_init=data.m_init, schedule=sched,
            )[0])
        run(jax.random.key(1))                   # warm-up: trace + compile
        # Min over reps, not mean: the ratio below gates CI, and min is the
        # noise-robust statistic on shared runners.
        dt = min(_timeit(lambda: run(jax.random.key(1)), reps=1, warmup=0)[0]
                 for _ in range(reps))
        record["schedules"][name] = {
            "seconds": dt,
            "per_center_s": dt / k,
            "buckets": list(sched.buckets()),
        }
        rows.append((f"adaptive_batch.{name}[n={n},k={k}]",
                     dt / k * 1e6, "per-center wall-clock"))
    ratio = (record["schedules"]["adaptive"]["per_center_s"]
             / record["schedules"]["fixed128"]["per_center_s"])
    record["adaptive_over_fixed128"] = ratio
    rows.append((f"adaptive_batch.ratio[n={n}]", 0.0,
                 f"adaptive/fixed128={ratio:.3f}"))
    return rows, record


def bench_plan_refit(n=1 << 14, d=16, k=16, refits=4):
    """Prepare-once / refit-many (ISSUE 4 acceptance row).

    Times the plan/execute lifecycle on the device rejection seeder: the
    first `fit` pays prepare (multi-tree embedding + LSH keys, O(nd log Δ)
    host work) plus the solve stage; every `refit(seed=...)` pays the solve
    stage only — zero host-side re-preparation and zero re-traces
    (`TRACE_COUNTS` is asserted by tests, the wall-clock win is recorded
    here so the cached-prepare advantage stays measurable across PRs).
    """
    from repro.core import ClusterPlan, ClusterSpec, ExecutionSpec

    rng = np.random.default_rng(0)
    ctr = rng.normal(size=(64, d)) * 20
    pts = ctr[rng.integers(64, size=n)] + rng.normal(size=(n, d))
    plan = ClusterPlan(
        ClusterSpec(k=k, seeder="rejection", seed=0,
                    options={"resolution": 0.05}, quantize=False),
        ExecutionSpec(backend="device"),
    )
    t0 = time.perf_counter()
    plan.prepare(pts)
    prepare_s = time.perf_counter() - t0
    first = plan.fit().block_until_ready()     # traces + compiles once
    refit_s = []
    for i in range(refits):
        t0 = time.perf_counter()
        plan.refit(seed=i + 1).block_until_ready()
        refit_s.append(time.perf_counter() - t0)
    best_refit = min(refit_s)
    record = {
        "n": n, "k": k, "d": d,
        "prepare_s": prepare_s,
        "first_fit_s": prepare_s + first.solve_seconds,
        "refit_s": best_refit,
        "refits": refits,
        "prepare_amortized_speedup":
            (prepare_s + best_refit) / max(best_refit, 1e-12),
        "cache": plan.cache_info(),
    }
    rows = [
        ("plan_refit.prepare[n=%d]" % n, prepare_s * 1e6,
         "host artifacts, paid once"),
        ("plan_refit.refit[n=%d]" % n, best_refit * 1e6,
         f"solve-only; prepare amortised "
         f"{record['prepare_amortized_speedup']:.1f}x"),
    ]
    return rows, record


def bench_pipeline(n=1 << 16, d=16, k=4, b=4):
    """Overlapped submit/solve vs the serial prepare+solve loop (ISSUE 5).

    `b` distinct n=2^16 datasets through the same ClusterSpec: the serial
    loop pays ``sum(prepare_i + solve_i)``; the `ClusterEngine` pipeline
    pays ``~ prepare_0 + sum(solve_i)`` because every later prepare runs
    on the host pool while the previous solve executes — the overlap
    speedup recorded here ("pipeline" section, CI-asserted > 1).  Results
    are bit-identical either way (the engine's determinism contract,
    tests/test_engine.py).  Also records the stacked multi-dataset
    `fit_batch`: the same b datasets as ONE vmapped program per shape
    bucket (all land in one bucket here).  The stacked row uses the
    fastkmeans++ seeder: a vmapped `lax.switch` (the rejection schedule)
    executes every branch per round, which interpret-mode CI cannot
    afford — the rejection stacked path is trace-count-asserted in
    tests/test_engine.py instead.
    """
    from repro.core import (
        ClusterEngine,
        ClusterPlan,
        ClusterSpec,
        ExecutionSpec,
        TRACE_COUNTS,
    )

    rng = np.random.default_rng(0)

    def make():
        ctr = rng.normal(size=(64, d)) * 20
        return ctr[rng.integers(64, size=n)] + rng.normal(size=(n, d))

    datasets = [make() for _ in range(b + 1)]
    spec = ClusterSpec(k=k, seeder="rejection", seed=0,
                       options={"resolution": 0.05}, quantize=False)
    exe = ExecutionSpec(backend="device")
    # Warm-up on a throwaway dataset: both paths then run the one cached
    # program (the measured quantity is throughput, not compile).
    warm = ClusterPlan(spec, exe)
    warm.prepare(datasets[0])
    warm.fit().block_until_ready()

    serial_plan = ClusterPlan(spec, exe)
    t0 = time.perf_counter()
    for ds in datasets[1:]:
        serial_plan.prepare(ds)
        serial_plan.fit().block_until_ready()
    serial_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    with ClusterEngine(spec, exe, prepare_workers=2) as engine:
        results = engine.map_fit(datasets[1:])
        for r in results:
            r.block_until_ready()
        st = engine.stats()
    pipelined_s = time.perf_counter() - t0
    speedup = serial_s / max(pipelined_s, 1e-9)

    traces0 = dict(TRACE_COUNTS)
    stacked_plan = ClusterPlan(
        ClusterSpec(k=k, seeder="fastkmeans++", seed=0), exe)
    t0 = time.perf_counter()
    stacked = stacked_plan.fit_batch(datasets=datasets[1:])
    stacked.block_until_ready()
    stacked_s = time.perf_counter() - t0
    stacked_traces = sum(
        v - traces0.get(kk, 0) for kk, v in TRACE_COUNTS.items()
        if kk.endswith("/stacked"))

    record = {
        "n": n, "d": d, "k": k, "num_problems": b,
        "serial_s": serial_s,
        "pipelined_s": pipelined_s,
        "overlap_speedup": speedup,
        "prepare_seconds_total": st["prepare_seconds"],
        "solve_seconds_total": st["solve_seconds"],
        "stacked_fit_batch_s": stacked_s,
        "stacked_shape_buckets": stacked.extras["shape_buckets"],
        "stacked_traces": stacked_traces,
    }
    rows = [
        (f"pipeline.serial[b={b},n={n}]", serial_s / b * 1e6,
         "per-problem prepare+solve, serial loop"),
        (f"pipeline.engine[b={b},n={n}]", pipelined_s / b * 1e6,
         f"overlap_speedup={speedup:.2f}x"),
        (f"pipeline.stacked_fit_batch[b={b},n={n}]", stacked_s / b * 1e6,
         f"{stacked.extras['shape_buckets']} bucket(s), "
         f"{stacked_traces} trace(s)"),
    ]
    return rows, record


def bench_robustness(n=1 << 12, d=16, k=4, b=16):
    """Goodput under injected faults (ISSUE 7 acceptance row).

    Drives `b` same-shape datasets through a `ClusterEngine` on the
    device backend while a seeded `FaultPlan` injects transient failures
    into 25% of primary solve attempts (`match` pins the chaos to
    fastkmeans++/device, so the degradation ladder — fastkmeans++/cpu,
    then kmeans++/cpu — stays healthy).  Each request retries up to 3
    attempts before falling back; goodput is the completed fraction and
    `stranded` counts tickets that never reached a terminal state — the
    CI gate (`check_regression.py`) requires goodput >= 0.95 and zero
    stranded.  Latency percentiles are per-request submit-to-done
    wall-clock, so the cost of a retry/fallback detour is visible in the
    p99/p50 spread across PRs.
    """
    import time as _time

    from repro.core import (
        ClusterEngine,
        ClusterSpec,
        ExecutionSpec,
        FaultPlan,
        RetryPolicy,
    )

    rng = np.random.default_rng(0)

    def make():
        ctr = rng.normal(size=(64, d)) * 20
        return ctr[rng.integers(64, size=n)] + rng.normal(size=(n, d))

    datasets = [make() for _ in range(b)]
    spec = ClusterSpec(k=k, seeder="fastkmeans++", seed=0)
    fault_plan = FaultPlan(seed=0, solve_failure_rate=0.25,
                           match="fastkmeans++/device")
    done_at: dict = {}
    t0 = _time.perf_counter()
    with ClusterEngine(spec, ExecutionSpec(backend="device"),
                       fault_plan=fault_plan,
                       retry=RetryPolicy(max_attempts=3)) as engine:
        submitted_at, tickets = [], []
        for ds in datasets:
            submitted_at.append(_time.perf_counter())
            ticket = engine.submit(ds, deadline=600.0)
            ticket.add_done_callback(
                lambda t: done_at.setdefault(t, _time.perf_counter()))
            tickets.append(ticket)
        failures = sum(t.exception() is not None for t in tickets)
        stats = engine.stats()
    wall_s = _time.perf_counter() - t0
    latencies = sorted(done_at[t] - s
                       for t, s in zip(tickets, submitted_at))
    terminal = stats["completed"] + stats["failed"] + stats["cancelled"]
    record = {
        "n": n, "d": d, "k": k, "requests": b,
        "solve_failure_rate": 0.25,
        "injected_faults": fault_plan.stats()["injected"],
        "goodput": stats["completed"] / b,
        "failures": failures,
        "stranded": stats["submitted"] - terminal,
        "retries": stats["retries"],
        "fallback_served": stats["fallback_served"],
        "short_circuited": stats["short_circuited"],
        "deadline_expired": stats["deadline_expired"],
        "latency_p50_s": float(np.percentile(latencies, 50)),
        "latency_p99_s": float(np.percentile(latencies, 99)),
        "wall_s": wall_s,
        "health": stats["health"],
    }
    rows = [
        (f"robustness.goodput[b={b},n={n}]", 0.0,
         f"goodput={record['goodput']:.3f} with "
         f"{record['injected_faults']} injected faults "
         f"({record['retries']} retries, "
         f"{record['fallback_served']} fallback-served)"),
        (f"robustness.latency_p50[b={b},n={n}]",
         record["latency_p50_s"] * 1e6, "submit-to-done"),
        (f"robustness.latency_p99[b={b},n={n}]",
         record["latency_p99_s"] * 1e6,
         "retry/fallback detours live in the p99/p50 spread"),
    ]
    return rows, record


def bench_serving(smoke: bool = False):
    """Continuous batching vs one-request-per-solve (ISSUE 8 acceptance).

    Replays ONE seeded open-loop Poisson arrival trace of mixed-(n, k, d)
    clustering traffic through two serving paths: a plain `ClusterEngine`
    (the PR-7 serving core — one stacked-solve dispatch per request) and
    the `ClusterFrontend` (hold-and-batch coalescing of compatible
    requests into stacked `fit_batch` lanes).  Both paths see identical
    arrival offsets and identical datasets, and every jit program either
    path can hit (solo per class; stacked per lane key at every
    power-of-two lane width up to ``max_batch``) is warmed before the
    timed window, so the measured quantity is steady-state serving
    throughput, not compile.  The fastkmeans++ seeder is used for the
    same reason as `bench_pipeline`: the rejection schedule's vmapped
    `lax.switch` cannot run stacked under interpret-mode CI.

    Records requests/sec, p50/p99 submit-to-done latency, mean lane
    occupancy and coalesce rate into the "serving" section of
    ``BENCH_seeding.json``; the CI gate (`check_regression.py`) requires
    coalescing to sustain >= 2x the one-request-per-solve requests/sec
    at no worse than serving-p99-slack times the baseline p99, with a
    minimum coalesce rate — the ISSUE 8 acceptance row.
    """
    import time as _time

    from repro.core import ClusterEngine, ClusterSpec, ExecutionSpec
    from repro.serving.frontend import ClusterFrontend

    n_requests = 48 if smoke else 96
    rate_hz = 400.0                 # open-loop: saturates the solo path
    max_batch = 8
    # Mixed n/k/d traffic: three lane keys across two shape buckets.  The
    # first two classes share (spec, d, bucket) and so coalesce together.
    classes = [
        dict(n=300, d=8, k=4),      # bucket 1024 - lane key A
        dict(n=900, d=8, k=4),      # bucket 1024 - lane key A (coalesces)
        dict(n=1300, d=8, k=4),     # bucket 2048 - lane key B
        dict(n=500, d=12, k=8),     # bucket 1024 - lane key C (k, d differ)
    ]
    rng = np.random.default_rng(8)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, size=n_requests))
    which = rng.integers(len(classes), size=n_requests)
    exe = ExecutionSpec(backend="device")
    specs = {c["k"]: ClusterSpec(k=c["k"], seeder="fastkmeans++", seed=0)
             for c in classes}

    def make(c):
        ctr = rng.normal(size=(8, c["d"])) * 20
        return (ctr[rng.integers(8, size=c["n"])]
                + rng.normal(size=(c["n"], c["d"])))

    datasets = [make(classes[i]) for i in which]
    warm_ds = [make(c) for c in classes]

    def replay(submit):
        """Drive the seeded trace; per-request latency via done-callbacks."""
        done: dict = {}
        tickets, sub_at = [], []
        t0 = _time.perf_counter()
        for off, ds, ci in zip(arrivals, datasets, which):
            now = _time.perf_counter() - t0
            if off > now:
                _time.sleep(off - now)
            sub_at.append(_time.perf_counter())
            t = submit(ds, classes[ci]["k"])
            t.add_done_callback(
                lambda tk: done.setdefault(tk, _time.perf_counter()))
            tickets.append(t)
        for t in tickets:
            t.result(timeout=600)
        wall = _time.perf_counter() - t0
        lats = sorted(done[t] - s for t, s in zip(tickets, sub_at))
        return wall, lats

    def _section(wall, lats):
        return {
            "wall_s": wall,
            "req_per_s": n_requests / wall,
            "latency_p50_s": float(np.percentile(lats, 50)),
            "latency_p99_s": float(np.percentile(lats, 99)),
        }

    # -- baseline: one solve dispatch per request ---------------------------
    with ClusterEngine(specs[4], exe, retain_prepared=False) as beng:
        for c, ds in zip(classes, warm_ds):     # warm each class's solo jit
            plan = beng.plan_for(specs[c["k"]])
            plan.fit_prepared(plan.prepare_data(ds)).block_until_ready()
        base_wall, base_lat = replay(
            lambda ds, k: beng.submit(ds, cluster=specs[k]))
    baseline = _section(base_wall, base_lat)

    # -- frontend: hold-and-batch coalescing over the same trace ------------
    feng = ClusterEngine(specs[4], exe, validate_inputs=False,
                         retain_prepared=False)
    with feng:
        for ci in (0, 2, 3):                    # one class per lane key
            plan = feng.plan_for(specs[classes[ci]["k"]])
            bp = 1
            while bp <= max_batch:              # every stacked lane width
                plan.fit_batch(
                    datasets=[warm_ds[ci]] * bp).block_until_ready()
                bp *= 2
        with ClusterFrontend(engine=feng, max_batch=max_batch,
                             max_wait_ms=8.0) as fe:
            fe_wall, fe_lat = replay(lambda ds, k: fe.submit(ds, k=k))
            st = fe.stats()
    frontend = _section(fe_wall, fe_lat)
    frontend.update(
        lanes=st["lanes"],
        mean_lane_occupancy=st["mean_lane_occupancy"],
        coalesce_rate=st["coalesce_rate"],
        flush_reasons={k[len("flush_"):]: v for k, v in st.items()
                       if k.startswith("flush_")},
    )
    record = {
        "requests": n_requests, "arrival_rate_hz": rate_hz,
        "max_batch": max_batch, "classes": classes,
        "baseline": baseline, "frontend": frontend,
        "speedup_req_per_s": frontend["req_per_s"] / baseline["req_per_s"],
        "p99_ratio_vs_baseline": (frontend["latency_p99_s"]
                                  / max(baseline["latency_p99_s"], 1e-12)),
    }
    rows = [
        (f"serving.baseline[b={n_requests}]",
         baseline["latency_p99_s"] * 1e6,
         f"one-request-per-solve: {baseline['req_per_s']:.1f} req/s"),
        (f"serving.frontend[b={n_requests}]",
         frontend["latency_p99_s"] * 1e6,
         f"coalesced: {frontend['req_per_s']:.1f} req/s, "
         f"occupancy={frontend['mean_lane_occupancy']:.2f}, "
         f"coalesce_rate={frontend['coalesce_rate']:.2f}"),
        (f"serving.speedup[b={n_requests}]", 0.0,
         f"req_per_s_speedup={record['speedup_req_per_s']:.2f}x "
         f"p99_ratio={record['p99_ratio_vs_baseline']:.2f}"),
    ]
    return rows, record


def bench_serving_net(smoke: bool = False):
    """Wire-transport overhead and tenant fairness (ISSUE 9 acceptance).

    Replays one seeded open-loop Poisson trace of two coalescible
    request classes through the SAME warmed engine twice: once via an
    in-process `ClusterFrontend` (the bench_serving fast path) and once
    over the `repro.serving.net` loopback RPC (`ClusterClient` ->
    `ClusterServer` sharing a second frontend on that engine, with a
    two-tenant `TenantScheduler` installed).  Both replays see identical
    arrival offsets, datasets and stacked-lane programs, so the wire
    numbers isolate what the transport adds: framing, socket hops, and
    result serialisation — not solve time and not compile.

    Records wire req/s, p50/p99 submit-to-done latency, the added p99
    and its ratio vs in-process, the per-tenant Jain fairness index
    (equal-weight tenants alternating on the trace: fair scheduling
    means near-equal median queue waits, J -> 1), and the server's
    queue_wait / solve / network attribution into
    ``BENCH_seeding.json["serving"]["net"]``.  CI gates the p99
    overhead ratio (`check_regression.py --net-max-p99-overhead`) and a
    fairness floor.
    """
    import threading as _threading
    import time as _time

    from repro.core import ClusterEngine, ClusterSpec, ExecutionSpec
    from repro.serving.frontend import ClusterFrontend
    from repro.serving.net import (
        ClusterClient, ClusterServer, TenantPolicy, TenantScheduler)

    n_requests = 32 if smoke else 64
    rate_hz = 400.0
    max_batch = 8
    # Two classes sharing one lane key (bucket 1024) so both paths
    # coalesce identically; tenants alternate with EQUAL weights, so a
    # fair scheduler shows near-equal per-tenant queue waits.
    classes = [dict(n=300, d=8), dict(n=900, d=8)]
    tenants = ("bulk", "batch")
    spec = ClusterSpec(k=4, seeder="fastkmeans++", seed=0)
    rng = np.random.default_rng(9)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, size=n_requests))
    which = rng.integers(len(classes), size=n_requests)

    def make(c):
        ctr = rng.normal(size=(8, c["d"])) * 20
        return (ctr[rng.integers(8, size=c["n"])]
                + rng.normal(size=(c["n"], c["d"])))

    datasets = [make(classes[i]) for i in which]
    exe = ExecutionSpec(backend="device")
    feng = ClusterEngine(spec, exe, validate_inputs=False,
                         retain_prepared=False)
    with feng:
        plan = feng.plan_for(spec)              # warm every lane width
        bp = 1
        while bp <= max_batch:
            plan.fit_batch(datasets=[datasets[0]] * bp).block_until_ready()
            bp *= 2

        def replay(submit):
            """Drive the trace open-loop; done-stamps via waiter threads."""
            done: dict = {}
            handles, sub_at, waiters = [], [], []
            t0 = _time.perf_counter()
            for i, (off, ds) in enumerate(zip(arrivals, datasets)):
                now = _time.perf_counter() - t0
                if off > now:
                    _time.sleep(off - now)
                sub_at.append(_time.perf_counter())
                h, wait = submit(ds, i)
                handles.append(h)

                def _stamp(h=h, wait=wait):
                    wait(h)
                    done[h] = _time.perf_counter()

                w = _threading.Thread(target=_stamp, daemon=True)
                w.start()
                waiters.append(w)
            for w in waiters:
                w.join(timeout=600)
            wall = _time.perf_counter() - t0
            lats = sorted(done[h] - s for h, s in zip(handles, sub_at))
            return {"wall_s": wall, "req_per_s": n_requests / wall,
                    "latency_p50_s": float(np.percentile(lats, 50)),
                    "latency_p99_s": float(np.percentile(lats, 99))}

        # Alternate timed replays of both paths and keep each path's
        # best rep (min-p99, the noise-robust statistic used across this
        # harness): the p99 of one short trace is nearly its max, so a
        # single rep on a shared CI runner measures scheduler jitter,
        # not transport overhead.  One untimed warm replay first pays
        # the residual prepare/compile warmup.
        reps = 3 if smoke else 5
        sched = TenantScheduler({t: TenantPolicy(weight=1.0)
                                 for t in tenants})
        fe2 = ClusterFrontend(engine=feng, max_batch=max_batch,
                              max_wait_ms=8.0, admission=sched)
        with ClusterFrontend(engine=feng, max_batch=max_batch,
                             max_wait_ms=8.0) as fe, \
                fe2, ClusterServer(frontend=fe2, port=0) as srv, \
                ClusterClient(*srv.address, read_timeout=600) as cl:
            replay(lambda ds, i: (                  # untimed warmup
                fe.submit(ds), lambda t: t.result(timeout=600)))
            inproc_reps, wire_reps = [], []
            for _ in range(reps):
                inproc_reps.append(replay(lambda ds, i: (
                    fe.submit(ds), lambda t: t.result(timeout=600))))
                wire_reps.append(replay(lambda ds, i: (
                    cl.submit(ds, tenant=tenants[i % len(tenants)]),
                    lambda rid: cl.result(rid, timeout=600))))
            inproc = min(inproc_reps, key=lambda r: r["latency_p99_s"])
            wire = min(wire_reps, key=lambda r: r["latency_p99_s"])
            st = srv.stats()

    waits = [float(rec["queue_wait"].get("p50") or 0.0)
             for rec in st.get("tenants", {}).values()]
    sq = sum(w * w for w in waits)              # Jain's fairness index
    fairness = ((sum(waits) ** 2 / (len(waits) * sq)) if sq > 0 else 1.0)
    record = {
        "requests": n_requests, "arrival_rate_hz": rate_hz,
        "max_batch": max_batch, "tenants": list(tenants),
        "inproc": inproc, "wire": wire,
        "req_per_s": wire["req_per_s"],
        "added_p99_s": wire["latency_p99_s"] - inproc["latency_p99_s"],
        "p99_overhead_ratio": (wire["latency_p99_s"]
                               / max(inproc["latency_p99_s"], 1e-12)),
        "fairness_index": float(fairness),
        "per_tenant": st.get("tenants", {}),
        "breakdown": st.get("net", {}).get("breakdown", {}),
    }
    rows = [
        (f"serving.net.wire[b={n_requests}]",
         wire["latency_p99_s"] * 1e6,
         f"loopback: {wire['req_per_s']:.1f} req/s, "
         f"p99_overhead={record['p99_overhead_ratio']:.2f}x "
         f"(+{record['added_p99_s'] * 1e3:.1f}ms)"),
        (f"serving.net.fairness[b={n_requests}]", 0.0,
         f"jain={record['fairness_index']:.3f} over "
         f"{len(waits)} equal-weight tenants"),
    ]
    return rows, record


def bench_streaming(smoke: bool = False, n=1 << 16, d=16, k=8,
                    batch_n=2048):
    """Incremental extend-then-refit vs re-prepare-then-fit (ISSUE 10).

    Grows ONE n=2^16 stream by `batch_n`-row batches two ways: the
    streaming path pays `ClusterPlan.extend` (frozen-scale quantise,
    incremental code/key encode, leaf-weight scatter — no re-prepare)
    plus a solve-only refit; the baseline re-prepares the concatenated
    dataset from scratch (full multi-tree embedding + LSH keys) and
    fits.  Both paths run the device rejection seeder on identical data
    and warmed jit programs (an untimed first round pays the streaming
    path's one-time capacity growth and both paths' compiles).

    The gated quantity (`check_regression.py --extend-beats-reprep`) is
    the per-round *incremental work* ratio — `extend` vs `prepare_data`
    — because that is what incrementality replaces; the solve-only
    refit is common to both paths and is recorded separately.  The
    end-to-end round latencies are recorded too, but NOT gated: off-TPU
    the interpret-mode solve dominates wall-clock and the streaming
    path solves at its capacity-padded shape bucket (2x the rows right
    after a growth), so end-to-end a from-scratch prepare can look
    competitive on CI while on hardware — where the solve is fast and
    the O(n d log Delta) host prepare dominates — the incremental path
    wins by the same prepare ratio gated here.

    Also records drift-reseed quality: a `StreamingController` ingests
    distribution-shifted batches until the cost-ratio EMA trips the
    `DriftPolicy` threshold; the gate requires >= 1 reseed to fire and
    the post-reseed cost to stay within a factor of a from-scratch fit
    on the same (drifted) live set.
    """
    from repro.core import (
        ClusterPlan,
        ClusterSpec,
        DriftPolicy,
        ExecutionSpec,
        StreamingController,
        clustering_cost,
    )

    rng = np.random.default_rng(0)
    ctr = rng.normal(size=(64, d)) * 20

    def draw(m, centers=ctr):
        return (centers[rng.integers(len(centers), size=m)]
                + rng.normal(size=(m, d)))

    timed = 2 if smoke else 4
    base = draw(n)
    batches = [draw(batch_n) for _ in range(timed + 1)]
    spec = ClusterSpec(k=k, seeder="rejection", seed=0,
                       options={"resolution": 0.05}, quantize=False)
    exe = ExecutionSpec(backend="device")

    # -- incremental: one stream, extend + solve-only refit per batch -------
    plan = ClusterPlan(spec, exe)
    t0 = time.perf_counter()
    prep = plan.prepare_streaming(base)
    stream_prepare_s = time.perf_counter() - t0
    plan.fit_prepared(prep).block_until_ready()
    # Untimed warm round: pays the one-time capacity growth (the stream
    # crosses its shape bucket here) and the grown solve program's trace.
    plan.extend(batches[0], prepared=prep)
    plan.fit_prepared(prep, seed=1).block_until_ready()
    ext_times, ext_refit_times = [], []
    for i, b in enumerate(batches[1:], start=2):
        t0 = time.perf_counter()
        plan.extend(b, prepared=prep)
        t1 = time.perf_counter()
        plan.fit_prepared(prep, seed=i).block_until_ready()
        ext_times.append(t1 - t0)
        ext_refit_times.append(time.perf_counter() - t1)
    stream_rebuilds = prep.streaming.rebuilds
    plan.forget(prep)

    # -- baseline: re-prepare the concatenated dataset from scratch ---------
    plan2 = ClusterPlan(spec, exe)
    acc = np.concatenate([base, batches[0]])
    pd = plan2.prepare_data(acc)                    # untimed warm round
    plan2.fit_prepared(pd, seed=1).block_until_ready()
    plan2.forget(pd)
    rep_times, rep_fit_times = [], []
    for i, b in enumerate(batches[1:], start=2):
        acc = np.concatenate([acc, b])
        t0 = time.perf_counter()
        pd = plan2.prepare_data(acc)
        t1 = time.perf_counter()
        plan2.fit_prepared(pd, seed=i).block_until_ready()
        rep_times.append(t1 - t0)
        rep_fit_times.append(time.perf_counter() - t1)
        plan2.forget(pd)

    extend_s = min(ext_times)
    reprep_s = min(rep_times)
    speedup = reprep_s / max(extend_s, 1e-12)

    # -- drift-reseed quality on a distribution shift -----------------------
    dn, dd, dk = 2048, 8, 8
    c_old = rng.normal(size=(dk, dd)) * 10
    c_new = -c_old + rng.normal(size=(dk, dd)) * 10
    dbase = c_old[rng.integers(dk, size=dn)] + rng.normal(size=(dn, dd))
    dplan = ClusterPlan(
        ClusterSpec(k=dk, seeder="rejection", seed=0,
                    options={"resolution": 0.05}, quantize=False), exe)
    ctrl = StreamingController(dplan, dbase,
                               drift=DriftPolicy(threshold=1.25, ema=0.5))
    history = []
    for _ in range(8):
        batch = (c_new[rng.integers(dk, size=512)]
                 + rng.normal(size=(512, dd)))
        history.append(ctrl.ingest(batch))
        if ctrl.reseeds:
            break
    live = ctrl.prepared.streaming.live_points()
    fresh_plan = ClusterPlan(dplan.cluster, exe)
    fresh_plan.prepare(live)
    fresh_cost = float(clustering_cost(
        live, np.asarray(fresh_plan.fit().centers, dtype=np.float64)))
    post_cost = ctrl.cost_now()
    quality_ratio = post_cost / max(fresh_cost, 1e-12)
    dplan.forget(ctrl.prepared)

    record = {
        "n": n, "d": d, "k": k, "batch_n": batch_n,
        "timed_batches": timed,
        "stream_prepare_s": stream_prepare_s,
        "extend_s": extend_s,
        "reprepare_s": reprep_s,
        "extend_speedup": speedup,
        "stream_refit_s": min(ext_refit_times),
        "reprepare_refit_s": min(rep_fit_times),
        "round_extend_refit_s": min(
            e + r for e, r in zip(ext_times, ext_refit_times)),
        "round_reprepare_fit_s": min(
            p + f for p, f in zip(rep_times, rep_fit_times)),
        "stream_rebuilds": stream_rebuilds,
        "drift": {
            "ingests": len(history),
            "reseeds": ctrl.reseeds,
            "peak_ratio": max(h["ratio"] for h in history),
            "post_reseed_cost": post_cost,
            "fresh_fit_cost": fresh_cost,
            "post_reseed_cost_ratio_vs_fresh": quality_ratio,
        },
    }
    rows = [
        (f"streaming.extend[n={n},b={batch_n}]", extend_s * 1e6,
         f"incremental mutation ({stream_rebuilds} rebuild(s)); "
         f"solve-only refit {min(ext_refit_times) * 1e3:.0f}ms rides on "
         f"the capacity-padded bucket"),
        (f"streaming.reprepare[n={n},b={batch_n}]", reprep_s * 1e6,
         f"from-scratch prepare of the concatenated rows; "
         f"extend_speedup={speedup:.1f}x"),
        (f"streaming.drift_reseed[n={dn}]", 0.0,
         f"reseeds={ctrl.reseeds} after {len(history)} shifted ingest(s), "
         f"post-reseed cost {quality_ratio:.2f}x a fresh fit"),
    ]
    return rows, record


def bench_heap_update(ns=(1 << 14, 1 << 16, 1 << 18), tile=512, reps=20):
    """Per-open sample-structure update: O(n) rebuild vs incremental.

    Times exactly the work a device seeder pays per opened center to keep
    its sample structure consistent AFTER the weight sweep: the old path
    rebuilt a full flat heap (`SampleTreeJax.init`, O(n)); the new path
    scatters the kernels' tile-sum epilogue into the coarse heap
    (`TiledSampleTree.refresh`, O(T log T), T = n/tile) — sublinear in n.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.sample_tree import SampleTreeJax, TiledSampleTree

    rng = np.random.default_rng(0)
    rows, record = [], {}
    for n in ns:
        w = jnp.asarray(rng.uniform(0.5, 2.0, n).astype(np.float32))
        st = SampleTreeJax(n)
        rebuild = jax.jit(st.init)
        # Min over reps (same statistic as bench_adaptive_batch): the
        # regression gate compares growth *ratios* across artifacts, and
        # the mean is dominated by scheduler noise at the ~50us small-n
        # end — exactly where a noise spike most distorts the ratio.
        dt_rebuild = min(
            _timeit(lambda: jax.block_until_ready(rebuild(w)),
                    reps=1, warmup=2 if r == 0 else 0)[0]
            for r in range(reps))
        ts = TiledSampleTree(n, tile=tile)
        coarse = ts.init(w)
        tsums = ts.tile_sums(w) * 0.9       # every tile touched (worst case)
        refresh = jax.jit(ts.refresh)
        dt_inc = min(
            _timeit(lambda: jax.block_until_ready(refresh(coarse, tsums)),
                    reps=1, warmup=2 if r == 0 else 0)[0]
            for r in range(reps))
        record[str(n)] = {
            "rebuild_s": dt_rebuild,
            "incremental_s": dt_inc,
            "speedup": dt_rebuild / max(dt_inc, 1e-12),
        }
        rows.append((f"heap_update.rebuild[n={n}]", dt_rebuild * 1e6, ""))
        rows.append((f"heap_update.incremental[n={n}]", dt_inc * 1e6,
                     f"speedup_vs_rebuild={dt_rebuild / max(dt_inc, 1e-12):.1f}x"))
    return rows, {"tile": tile, "per_open": record}


def write_bench_json(seed_results, heap_update, adaptive_batch, plan_refit,
                     pipeline, robustness, serving, streaming, *,
                     smoke: bool):
    """BENCH_seeding.json: the cross-PR perf-trajectory artifact."""
    import jax

    datasets = []
    for res in seed_results:
        base = res["algos"].get("kmeans++", {}).get("cost", {})
        algos = {}
        for algo, data in res["algos"].items():
            algos[algo] = {
                "seconds": {str(k): v for k, v in data["seconds"].items()},
                "prepare_seconds": {
                    str(k): v
                    for k, v in data.get("prepare_seconds", {}).items()
                },
                "solve_seconds": {
                    str(k): v
                    for k, v in data.get("solve_seconds", {}).items()
                },
                "cost": {str(k): v for k, v in data["cost"].items()},
                "cost_ratio_vs_kmeanspp": {
                    str(k): v / base[k]
                    for k, v in data["cost"].items() if base.get(k)
                },
            }
        datasets.append({"dataset": res["dataset"], "n": res["n"],
                         "d": res["d"], "ks": res["ks"], "algos": algos})
    payload = {
        "generated_by": "benchmarks/run.py" + (" --smoke" if smoke else ""),
        "backend": jax.default_backend(),
        "interpret_kernels": jax.default_backend() != "tpu",
        "num_devices": len(jax.devices()),
        "datasets": datasets,
        "heap_update_per_open": heap_update,
        "adaptive_batch": adaptive_batch,
        "plan_refit": plan_refit,
        "pipeline": pipeline,
        "robustness": robustness,
        "serving": serving,
        "streaming": streaming,
    }
    BENCH_JSON.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {BENCH_JSON}")


def bench_roofline():
    rows = []
    try:
        from benchmarks.roofline import analyze, load_cells

        for rec in load_cells("pod"):
            a = analyze(rec)
            if a is None:
                continue
            dom = max(a["t_compute"], a["t_memory"], a["t_collective"])
            rows.append((
                f"roofline.{a['arch']}.{a['shape']}",
                dom * 1e6,
                f"bound={a['bottleneck']};roofline={a['roofline_fraction']:.2f}",
            ))
    except Exception as e:  # artifacts may not exist yet
        rows.append(("roofline.unavailable", 0.0, repr(e)[:60]))
    return rows


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized seeding run (CPU + device backends), "
                         "skipping the heavier microbenchmarks")
    ap.add_argument("--only", choices=["serving", "streaming"],
                    default=None,
                    help="re-run a single section and merge its record "
                         "into the existing BENCH_seeding.json (CI uses "
                         "`--only serving` and `--only streaming` as "
                         "named gate steps)")
    ap.add_argument("--transport", choices=["inproc", "net"],
                    default="inproc",
                    help="with `--only serving`: `net` re-measures just "
                         "the loopback wire transport (bench_serving_net) "
                         "and merges it as serving.net, leaving the "
                         "in-process record untouched")
    args = ap.parse_args(argv)
    all_rows = []
    if args.only == "streaming":
        payload = json.loads(BENCH_JSON.read_text())
        print("# streaming: incremental extend vs re-prepare, drift reseed",
              flush=True)
        st_rows, streaming = bench_streaming(smoke=args.smoke)
        payload["streaming"] = streaming
        BENCH_JSON.write_text(json.dumps(payload, indent=2) + "\n")
        print(f"merged streaming section into {BENCH_JSON}")
        print("\nname,us_per_call,derived")
        for name, us, derived in st_rows:
            print(f"{name},{us:.1f},{derived}")
        return
    if args.only == "serving":
        payload = json.loads(BENCH_JSON.read_text())
        prior = payload.get("serving", {})
        if args.transport == "net":
            print("# serving.net: loopback wire transport vs in-process",
                  flush=True)
            sv_rows, net = bench_serving_net(smoke=args.smoke)
            prior["net"] = net
            payload["serving"] = prior
        else:
            print("# serving: continuous batching vs one-request-per-solve",
                  flush=True)
            sv_rows, serving = bench_serving(smoke=args.smoke)
            if "net" in prior:        # keep the wire subsection current
                serving["net"] = prior["net"]
            payload["serving"] = serving
        BENCH_JSON.write_text(json.dumps(payload, indent=2) + "\n")
        print(f"merged serving section into {BENCH_JSON}")
        print("\nname,us_per_call,derived")
        for name, us, derived in sv_rows:
            print(f"{name},{us:.1f},{derived}")
        return
    print("# seeding tables (paper tables 1-8, CI scale)", flush=True)
    seed_rows, seed_results = bench_seeding(smoke=args.smoke)
    all_rows += seed_rows
    print("# per-open heap update: rebuild vs incremental", flush=True)
    heap_rows, heap_update = bench_heap_update()
    all_rows += heap_rows
    print("# adaptive vs fixed candidate batching (n=2^16)", flush=True)
    ab_rows, adaptive_batch = bench_adaptive_batch()
    all_rows += ab_rows
    print("# plan/execute: prepare-once / refit-many", flush=True)
    pr_rows, plan_refit = bench_plan_refit()
    all_rows += pr_rows
    print("# pipeline: overlapped engine vs serial prepare+solve (n=2^16)",
          flush=True)
    pl_rows, pipeline = bench_pipeline()
    all_rows += pl_rows
    print("# robustness: goodput under a seeded FaultPlan", flush=True)
    rb_rows, robustness = bench_robustness()
    all_rows += rb_rows
    print("# serving: continuous batching vs one-request-per-solve",
          flush=True)
    sv_rows, serving = bench_serving(smoke=args.smoke)
    all_rows += sv_rows
    print("# serving.net: loopback wire transport vs in-process",
          flush=True)
    net_rows, serving["net"] = bench_serving_net(smoke=args.smoke)
    all_rows += net_rows
    print("# streaming: incremental extend vs re-prepare, drift reseed",
          flush=True)
    st_rows, streaming = bench_streaming(smoke=args.smoke)
    all_rows += st_rows
    if not args.smoke:
        print("# kernel microbenchmarks", flush=True)
        all_rows += bench_kernels()
        all_rows += bench_roofline()
    write_bench_json(seed_results, heap_update, adaptive_batch, plan_refit,
                     pipeline, robustness, serving, streaming,
                     smoke=args.smoke)
    print("\nname,us_per_call,derived")
    for name, us, derived in all_rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
